"""Open-loop load benchmark + fault-injection soak for the serving front
end (`repro.serve.frontend.ServeFrontend`).

Traffic model: **open-loop Poisson arrivals** (exponential inter-arrival
times at a configured offered rate — arrivals do not wait for responses,
so overload actually overloads) over **Zipf-distributed** models and
networks (a small hot set dominates, as real serving traffic does, which
exercises the result cache and request coalescing) with a small seed pool
(verbatim repeats) and a deadline on a fraction of requests.

The run sweeps offered load as multiples of the measured saturation
throughput (0.5x -> 2x) and records a latency-vs-offered-load curve —
p50/p99 served latency, achieved throughput, shed rate, cache hit rate —
appended to the repo-root ``BENCH_load.json`` trajectory (latest copy in
``results/load_serving.json``).

Every point runs with **fault injection on** (`FaultPlan`: a deterministic
device-route error burst + seeded latency spikes), so each point is also a
soak: the run FAILS (nonzero exit) unless

- every submitted request terminates (DONE / FAILED / REJECTED — zero
  wedged futures);
- every served response is Selection-identical to a standalone
  ``explore`` of the same (network, objectives, seed) — faults, retries,
  and the degraded route are invisible to correctness;
- the degraded host-route fallback activates under the burst and recovers
  after it;
- load shedding activates at the overload point (2x saturation) while
  queue depth stays within the admission bound (bounded memory);
- served p99 at the sub-saturation point stays under ``--max-p99-ms``.

  PYTHONPATH=src python benchmarks/bench_load.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel
from repro.serve import (DSEServer, FaultPlan, FaultyEngine, FrontendConfig,
                         ServeConfig, ServeFrontend)

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
TRAJECTORY = os.environ.get("REPRO_BENCH_LOAD_TRAJECTORY", "BENCH_load.json")

MAX_BATCH = 8
MAX_QUEUE = 16          # per-model admission bound (the memory cap under test)
TASK_POOL = 24          # distinct networks per model (Zipf ranks)
SEED_POOL = 16          # distinct request seeds (repeats -> cache hits)
DEADLINE_FRAC = 0.25    # fraction of requests carrying a deadline


# ---------------------------------------------------------------------------
# engines and traffic
# ---------------------------------------------------------------------------
def build_engines(quick: bool) -> Dict[str, GANDSE]:
    """One random-init engine per design model (throughput and robustness
    do not depend on training quality — same rationale as bench_serve)."""
    layers, neurons = (1, 64) if quick else (2, 128)
    out = {}
    for i, model in enumerate((DnnWeaverModel(), Im2colModel())):
        cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
            layers=layers, neurons=neurons, batch_size=64)
        eng = GANDSE(model, cfg, ExplorerConfig(prob_threshold=0.1,
                                                max_candidates=256))
        ds = generate_dataset(model, 256, seed=i)
        key = jax.random.fold_in(jax.random.PRNGKey(3), i)
        eng.attach(ds, G.init_generator(key, cfg, model.space))
        out[model.name] = eng
    return out


def _zipf_weights(k: int, a: float = 1.1) -> np.ndarray:
    w = 1.0 / np.arange(1, k + 1) ** a
    return w / w.sum()


def make_traffic(engines: Dict[str, GANDSE], n: int, seed: int
                 ) -> Tuple[Dict[str, object], List[Tuple[str, int, int]]]:
    """Zipf-skewed request stream: (model_name, task_row, seed) triples
    drawn from small hot pools, plus the per-model task pools themselves."""
    rng = np.random.default_rng(seed)
    names = sorted(engines)
    pools = {m: generate_tasks(engines[m].model, TASK_POOL, seed=2 + i)
             for i, m in enumerate(names)}
    m_idx = rng.choice(len(names), size=n, p=_zipf_weights(len(names)))
    rows = rng.choice(TASK_POOL, size=n, p=_zipf_weights(TASK_POOL))
    seeds = rng.integers(0, SEED_POOL, size=n)
    stream = [(names[m], int(r), int(s))
              for m, r, s in zip(m_idx, rows, seeds)]
    return pools, stream


def warmup(engines: Dict[str, GANDSE], pools) -> None:
    """Compile every dispatch shape the run will hit (pow2 micro-batch
    buckets with per-row seed arrays, the sequential host route, and the
    single-explore path the parity check uses) so compilation never lands
    inside a timed window."""
    for name, eng in engines.items():
        tasks = pools[name]
        k = 1
        while k <= MAX_BATCH:
            eng.explore_tasks(tasks.take(np.arange(k) % TASK_POOL),
                              seed=np.arange(k))
            k *= 2
        eng.explore_tasks(tasks.take(np.arange(2)), seed=np.arange(2),
                          batched=False)
        eng.explore(tasks.net_idx[0], tasks.lat_obj[0], tasks.pow_obj[0],
                    seed=0)


def measure_saturation(engines, pools, quick: bool) -> float:
    """Closed-loop ceiling: requests/s of a full drain with unique seeds
    through a healthy server — the load points are multiples of this."""
    n = 32 if quick else 64
    srv = DSEServer(ServeConfig(max_batch=MAX_BATCH, cache_capacity=0))
    for eng in engines.values():
        srv.register(eng)
    names = sorted(engines)
    t0 = time.perf_counter()
    for i in range(n):
        m = names[i % len(names)]
        t = pools[m]
        srv.submit(m, t.net_idx[i % TASK_POOL], t.lat_obj[i % TASK_POOL],
                   t.pow_obj[i % TASK_POOL], seed=10_000 + i)
    assert len(srv.drain()) == n
    return n / (time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# one load point (= one fault-injected soak)
# ---------------------------------------------------------------------------
def run_point(engines, pools, stream, rate: float, deadline_s: float,
              seed: int) -> Dict:
    fault_plans = {}
    srv = DSEServer(ServeConfig(
        max_batch=MAX_BATCH, max_queue=MAX_QUEUE,
        max_dispatch_attempts=8, retry_backoff_base=0.005,
        retry_backoff_max=0.25, degrade_after=2, degrade_probe_after=1))
    for i, (name, eng) in enumerate(sorted(engines.items())):
        # deterministic burst early in the Zipf-hot model's dispatch stream
        # (the tail model may see too few post-burst dispatches in a short
        # overload blast to re-probe, so it gets latency spikes only), with
        # the host route immune so the degraded fallback genuinely recovers
        # burst_len == degrade_after: the first recovery probe lands just
        # past the burst window, so recovery completes within two post
        # -burst dispatches even in a short overload blast
        plan = FaultPlan(seed=seed + i,
                         burst_start=2, burst_len=2 if i == 0 else 0,
                         spike_rate=0.05, spike_s=0.01,
                         device_route_only=True)
        fault_plans[name] = FaultyEngine(eng, plan)
        srv.register(fault_plans[name])

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(stream))
    records = []                    # (future, t_submit, box-for-t_done)
    max_pending = [0]
    stop_sampling = threading.Event()

    def sampler():                  # bounded-queue-memory witness
        while not stop_sampling.is_set():
            max_pending[0] = max(max_pending[0], srv.batcher.pending())
            time.sleep(0.002)

    sam = threading.Thread(target=sampler, daemon=True)
    sam.start()
    t_start = time.perf_counter()
    with ServeFrontend(srv, FrontendConfig(admission="reject")) as fe:
        next_at = t_start
        for j, (name, row, rseed) in enumerate(stream):
            next_at += gaps[j]
            delay = next_at - time.perf_counter()
            if delay > 0:           # open loop: never waits on responses,
                time.sleep(delay)   # only on the arrival process
            t = pools[name]
            timeout = deadline_s if rng.random() < DEADLINE_FRAC else None
            t0 = time.perf_counter()
            fut = fe.submit(name, t.net_idx[row], t.lat_obj[row],
                            t.pow_obj[row], seed=rseed, timeout_s=timeout)
            done_at = []
            fut.add_done_callback(
                lambda _f, d=done_at: d.append(time.perf_counter()))
            records.append((fut, t0, done_at))
        fe.wait_all(timeout=300.0)  # wedged futures counted precisely below
    elapsed = time.perf_counter() - t_start
    stop_sampling.set()
    sam.join(1.0)

    resps, served_lat = [], []
    wedged = 0
    for fut, t0, done_at in records:
        if not fut.done():
            wedged += 1
            continue
        r = fut.result()
        resps.append(r)
        if r.ok:
            served_lat.append((done_at[0] if done_at else time.perf_counter())
                              - t0)
    lat = np.asarray(sorted(served_lat), np.float64) * 1e3
    n_ok = sum(r.ok for r in resps)
    n_rej = sum(r.rejected for r in resps)
    n_fail = sum(r.source == "failed" for r in resps)
    cache = srv.cache.stats()
    faults = {m: f.fault_stats() for m, f in fault_plans.items()}
    return {
        "offered_rps": rate,
        "n_requests": len(stream),
        "achieved_rps": n_ok / max(elapsed, 1e-9),
        "elapsed_s": elapsed,
        "p50_ms": float(np.percentile(lat, 50)) if lat.size else None,
        "p99_ms": float(np.percentile(lat, 99)) if lat.size else None,
        "served": n_ok,
        "rejected": n_rej,
        "failed": n_fail,
        "wedged": wedged,
        "shed_rate": n_rej / len(stream),
        "cache_hit_rate": (cache["hits"] / max(cache["hits"]
                                               + cache["misses"], 1)),
        "coalesced": srv.stats["coalesced"],
        "degraded_entered": srv.stats["degraded_entered"],
        "degraded_recovered": srv.stats["degraded_recovered"],
        "degraded_responses": sum(r.degraded for r in resps),
        "injected_errors": sum(f["injected_errors"] for f in faults.values()),
        "injected_spikes": sum(f["injected_spikes"] for f in faults.values()),
        "max_pending_seen": max_pending[0],
        "_responses": resps,        # stripped before JSON; parity check input
    }


def check_parity(engines, pools, stream, resps) -> Tuple[int, List[str]]:
    """Every served response must be Selection-identical to a standalone
    `explore` of its (network, objectives, seed) on the bare engine —
    batching, caching, retries, and the degraded route all invisible."""
    by_rid = {}                     # rid -> (model, row, seed), admission order
    rid = 0
    for name, row, rseed in stream:
        by_rid[rid] = (name, row, rseed)
        rid += 1
    direct = {}
    failures = []
    checked = 0
    for r in resps:
        if not r.ok:
            continue
        name, row, rseed = by_rid[r.rid]
        key = (name, row, rseed)
        if key not in direct:
            t = pools[name]
            direct[key] = engines[name].explore(
                t.net_idx[row], t.lat_obj[row], t.pow_obj[row], seed=rseed)
        sa, sb = r.result.selection, direct[key].selection
        checked += 1
        same = (sa.n_candidates == sb.n_candidates
                and (sa.cfg_idx is None) == (sb.cfg_idx is None)
                and (sa.cfg_idx is None
                     or np.array_equal(sa.cfg_idx, sb.cfg_idx))
                and sa.latency == sb.latency and sa.power == sb.power)
        if not same:
            failures.append(f"rid {r.rid} ({key}): served Selection != "
                            f"standalone explore")
    return checked, failures


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def run(quick: bool, max_p99_ms: float) -> Tuple[Dict, List[str]]:
    engines = build_engines(quick)
    n_point = 100 if quick else 200
    pools, _ = make_traffic(engines, 1, seed=0)
    warmup(engines, pools)
    sat = measure_saturation(engines, pools, quick)
    print(f"[load] saturation ~{sat:.0f} req/s "
          f"(backend={jax.default_backend()})", flush=True)

    mults = (0.5, 2.0) if quick else (0.5, 1.0, 2.0)
    deadline_s = 2.0 if quick else 5.0
    failures: List[str] = []
    points = []
    for k, mult in enumerate(mults):
        _, stream = make_traffic(engines, n_point, seed=100 + k)
        pt = run_point(engines, pools, stream, rate=max(sat * mult, 1.0),
                       deadline_s=deadline_s, seed=1000 + k)
        resps = pt.pop("_responses")
        pt["load_multiplier"] = mult
        n_checked, bad = check_parity(engines, pools, stream, resps)
        pt["parity_checked"] = n_checked
        failures += bad
        points.append(pt)
        print(f"[load] {mult:.1f}x sat ({pt['offered_rps']:.0f} rps offered): "
              f"served={pt['served']} rejected={pt['rejected']} "
              f"failed={pt['failed']} wedged={pt['wedged']} "
              f"p50={pt['p50_ms'] and round(pt['p50_ms'], 1)}ms "
              f"p99={pt['p99_ms'] and round(pt['p99_ms'], 1)}ms "
              f"cache={pt['cache_hit_rate']:.0%} "
              f"degraded={pt['degraded_entered']}/{pt['degraded_recovered']} "
              f"parity={n_checked}", flush=True)

        # --- soak gates, per point ---------------------------------------
        tag = f"{mult:.1f}x"
        if pt["wedged"]:
            failures.append(f"{tag}: {pt['wedged']} request(s) never "
                            f"terminated (wedged futures)")
        if pt["served"] + pt["rejected"] + pt["failed"] != n_point:
            failures.append(f"{tag}: responses do not partition the stream")
        if pt["injected_errors"] > 0 and pt["degraded_entered"] < 1:
            failures.append(f"{tag}: fault burst never tripped the degraded "
                            f"fallback")
        if pt["degraded_entered"] > 0 and pt["degraded_recovered"] < 1:
            failures.append(f"{tag}: degraded fallback never recovered")
        # per-model ceiling: max_queue admitted at the door + a failed
        # batch requeued at the head (already-admitted work is never shed
        # by the bound, so it can transiently sit on top of a full queue)
        bound = (MAX_QUEUE + MAX_BATCH) * len(engines)
        if pt["max_pending_seen"] > bound:
            failures.append(f"{tag}: queue depth {pt['max_pending_seen']} "
                            f"exceeded the admission bound ({bound} = "
                            f"(max_queue+max_batch) x {len(engines)} "
                            f"models)")
    if points[0]["p99_ms"] is not None and points[0]["p99_ms"] > max_p99_ms:
        failures.append(f"sub-saturation p99 {points[0]['p99_ms']:.0f}ms "
                        f"> {max_p99_ms:.0f}ms bound")
    if points[-1]["rejected"] == 0:
        failures.append("no load shedding at 2x saturation (admission "
                        "control inert)")

    out = {
        "quick": quick,
        "backend": jax.default_backend(),
        "saturation_rps": sat,
        "max_batch": MAX_BATCH,
        "max_queue": MAX_QUEUE,
        "points": points,
        "ok": not failures,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "load_serving.json"), "w") as f:
        json.dump(out, f, indent=1)
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f)
    traj.append(out)
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)
    return out, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI soak scale: ~200 requests over 2 load points, "
                         "smaller G")
    ap.add_argument("--max-p99-ms", type=float, default=5000.0,
                    help="fail if served p99 at the 0.5x-saturation point "
                         "exceeds this (loose bound for noisy runners)")
    args = ap.parse_args(argv)
    _, failures = run(quick=args.quick, max_p99_ms=args.max_p99_ms)
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print("ok: all requests terminated, served responses parity-checked, "
          "degraded fallback cycled, shedding bounded the queues")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
