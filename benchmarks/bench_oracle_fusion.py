"""Oracle fusion benchmark: callback vs device-resident Algorithm 1.

Two comparisons, both at the repo's CI DSE-GAN scale:

1. **step**: the jitted per-batch update with the design-model oracle
   (a) through ``jax.pure_callback`` to host numpy (the original route) vs
   (b) fused into the step as pure jnp (``DesignModel.evaluate_jax``).
2. **loop**: the seed implementation's full per-batch hot path (host batch
   re-encode + upload + callback step) vs the shipped ``train_gan`` hot
   path (one ``lax.scan`` per epoch over pre-encoded device batches).

  PYTHONPATH=src python benchmarks/bench_oracle_fusion.py [--quick]

Timings are interleaved min-of-trials (CPU CI boxes are noisy).  The
acceptance bar: for every model the fused hot path must be >= 2x faster
than the callback route — the raw step comparison at --quick scale (where
oracle overhead dominates; it reaches 4-7x there), and at the larger
default scale at least one of {step, loop} (big-net compute amortizes the
per-step callback cost, but the shipped scanned loop stays >= 2x).  The
script exits nonzero otherwise.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as G
from repro.core.train import (encode_batch, encode_dataset, make_epoch_fn,
                              make_train_step)
from repro.dataset.generator import generate_dataset
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
TRIALS = 5


def _init(model, cfg, seed=0):
    rng = jax.random.PRNGKey(seed)
    rng, g_rng, d_rng = jax.random.split(rng, 3)
    g_params = G.init_generator(g_rng, cfg, model.space)
    d_params = G.init_discriminator(d_rng, cfg, model.space)
    return g_params, d_params, rng


def _contenders(model, cfg, ds, steps):
    """Build the timed closures: each returns after `steps` batch updates,
    blocking on the last metric."""
    bs = min(cfg.batch_size, ds.n)
    g_params, d_params, rng = _init(model, cfg)
    fixed = {k: jnp.asarray(v)
             for k, v in encode_batch(model, ds, np.arange(bs)).items()}

    out = {}
    for name, use in (("step_callback", False), ("step_fused", True)):
        g_optim, d_optim, step = make_train_step(model, cfg,
                                                 use_jax_oracle=use)
        st = [g_params, d_params, g_optim.init(g_params),
              d_optim.init(d_params), rng]

        def run(st=st, step=step):
            for _ in range(steps):
                (st[0], st[1], st[2], st[3], st[4], m) = step(
                    st[0], st[1], st[2], st[3], fixed, st[4])
            jax.block_until_ready(m["loss_g"])

        out[name] = run

    # seed hot path: per-batch host re-encode + upload + callback step
    g_optim, d_optim, step = make_train_step(model, cfg, use_jax_oracle=False)
    st_seed = [g_params, d_params, g_optim.init(g_params),
               d_optim.init(d_params), rng]
    perm_rng = np.random.default_rng(0)

    def run_seed(st=st_seed, step=step):
        for _ in range(steps):
            idx = perm_rng.permutation(ds.n)[:bs]
            batch = {k: jnp.asarray(v)
                     for k, v in encode_batch(model, ds, idx).items()}
            (st[0], st[1], st[2], st[3], st[4], m) = step(
                st[0], st[1], st[2], st[3], batch, st[4])
        jax.block_until_ready(m["loss_g"])

    out["loop_seed"] = run_seed

    # shipped hot path: one scan per epoch over pre-gathered device batches
    g_optim, d_optim, epoch = make_epoch_fn(model, cfg)
    data = encode_dataset(model, ds)
    n_batches = max(ds.n // bs, 1)
    n_epochs = max(steps // n_batches, 1)
    carry0 = (g_params, d_params, g_optim.init(g_params),
              d_optim.init(d_params), rng)
    state = {"carry": carry0}

    def run_scan(state=state):
        carry = state["carry"]
        for e in range(n_epochs):
            perm = jnp.asarray(
                np.random.default_rng(e).permutation(ds.n)[: n_batches * bs]
                .reshape(n_batches, bs).astype(np.int32))
            carry, m = epoch(carry, data, perm)
        state["carry"] = carry
        jax.block_until_ready(m["loss_g"])

    out["loop_scan"] = run_scan
    out["_norm"] = {"step_callback": steps, "step_fused": steps,
                    "loop_seed": steps, "loop_scan": n_epochs * n_batches}
    return out


def bench_model(model, cfg, ds, steps) -> Dict[str, float]:
    contenders = _contenders(model, cfg, ds, steps)
    norm = contenders.pop("_norm")
    for run in contenders.values():          # warmup / compile
        run()
    best = {k: float("inf") for k in contenders}
    for _ in range(TRIALS):                  # interleaved: noise-robust
        for name, run in contenders.items():
            t0 = time.perf_counter()
            run()
            best[name] = min(best[name],
                             (time.perf_counter() - t0) / norm[name])
    return {k: v * 1e3 for k, v in best.items()}     # ms per batch


def run(quick: bool = False) -> Dict:
    scale = dict(layers=1, neurons=64, batch_size=128, n_data=512,
                 steps=15) if quick else \
            dict(layers=2, neurons=128, batch_size=256, n_data=2048,
                 steps=40)
    out = {}
    for model in (DnnWeaverModel(), Im2colModel()):
        cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
            layers=scale["layers"], neurons=scale["neurons"],
            batch_size=scale["batch_size"], lr=1e-4)
        ds = generate_dataset(model, scale["n_data"], seed=0)
        t = bench_model(model, cfg, ds, scale["steps"])
        t["step_speedup"] = t["step_callback"] / t["step_fused"]
        t["loop_speedup"] = t["loop_seed"] / t["loop_scan"]
        out[model.name] = t
        print(f"[oracle_fusion:{model.name}] "
              f"step callback={t['step_callback']:.2f}ms "
              f"fused={t['step_fused']:.2f}ms ({t['step_speedup']:.1f}x) | "
              f"loop seed={t['loop_seed']:.2f}ms/batch "
              f"scanned={t['loop_scan']:.2f}ms/batch "
              f"({t['loop_speedup']:.1f}x)", flush=True)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "oracle_fusion.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale (<1 min on CPU)")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="fail below this fused-vs-callback ratio; use a "
                         "loose bound (e.g. 1.0) on noisy shared runners")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    # at --quick scale the raw step comparison itself must clear the bar
    # (oracle overhead dominates there); at the larger default scale the
    # big-net compute amortizes the per-step callback cost, so either the
    # step or the shipped scanned-loop comparison may carry it.
    worst = min(r["step_speedup"] if args.quick
                else max(r["step_speedup"], r["loop_speedup"])
                for r in out.values())
    if worst < args.min_speedup:
        print(f"FAIL: fused hot path only {worst:.2f}x faster "
              f"(< {args.min_speedup:g}x bar)")
        return 1
    print(f"ok: fused hot path >= {worst:.1f}x faster than the callback "
          f"route on every model "
          f"(step {[round(r['step_speedup'], 1) for r in out.values()]}x, "
          f"loop {[round(r['loop_speedup'], 1) for r in out.values()]}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
