"""Paper Figs. 8-9: result distribution — log2(LO/L_opt), log2(PO/P_opt)
per DSE result, plus quadrant occupancy (1st quadrant = both satisfied)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_all_methods, write_json


def run(models=("dnnweaver", "im2col")) -> dict:
    out = {}
    for model_name in models:
        rows = []
        for mr in run_all_methods(model_name):
            xs, ys = [], []
            for r in mr.results:
                if not np.isfinite(r.selection.latency):
                    continue
                xs.append(float(np.log2(r.lat_obj / r.selection.latency)))
                ys.append(float(np.log2(r.pow_obj / r.selection.power)))
            xs, ys = np.asarray(xs), np.asarray(ys)
            quad = {
                "q1_both_sat": float(np.mean((xs >= 0) & (ys >= 0))),
                "q2_lat_fail": float(np.mean((xs < 0) & (ys >= 0))),
                "q4_pow_fail": float(np.mean((xs >= 0) & (ys < 0))),
                "q3_both_fail": float(np.mean((xs < 0) & (ys < 0))),
            }
            tag = mr.method + (f"(w={mr.w_critic})" if mr.w_critic is not None else "")
            rows.append({"method": tag, "quadrants": quad,
                         "points": [xs.tolist(), ys.tolist()]})
            print(f"[distribution:{model_name}] {tag:14s} "
                  + " ".join(f"{k}={v:.2f}" for k, v in quad.items()),
                  flush=True)
        out[model_name] = rows
    write_json("distribution.json", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
